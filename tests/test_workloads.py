"""Wavelet packets + 3-D (t+2D) workloads: deterministic coverage.

The differential harness (tests/test_differential.py) samples the
config space randomly; this file pins the acceptance matrix
deterministically — every scheme round-trips packet and 3-D transforms
at depth/levels >= 2 on odd/prime shapes — plus the tree algebra, the
plan-layer wiring (demotion, caching, capability gating) and the
serving exposure.
"""
import asyncio

import numpy as np
import pytest

from repro import engine
from repro.core import (PacketTree, best_basis, dwt2, dwt3, idwt3, iwpt2,
                        wpt2)
from repro.core.packets import best_basis_from_costs, cost_shannon
from repro.core.schemes import SCHEMES
from repro.engine.backends import BackendError, get_backend
from repro.engine.cache import PlanCache, get_plan

RTOL, ATOL = 1e-3, 1e-4


def _img(shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape) \
        .astype(np.float32)


# -- PacketTree algebra -------------------------------------------------

def test_packet_tree_canonicalization_and_spellings():
    # every spelling of the same tree normalizes to one leaf tuple
    t1 = PacketTree.from_spec("full:2")
    t2 = PacketTree.from_spec(reversed(t1.leaves))
    t3 = PacketTree.from_spec(PacketTree.full(2))
    assert t1.leaves == t2.leaves == t3.leaves
    assert t1.depth == 2 and len(t1) == 16
    assert PacketTree.pyramid(3).leaves == PacketTree.from_spec(
        "dwt:3").leaves


def test_packet_tree_rejects_inadmissible_sets():
    with pytest.raises(ValueError):                   # incomplete
        PacketTree.from_spec(("a", "h", "v"))
    with pytest.raises(ValueError):                   # prefix overlap
        PacketTree.from_spec(("a", "h", "v", "d", "aa"))
    with pytest.raises(ValueError):                   # root as leaf
        PacketTree.from_spec(("",))
    with pytest.raises(ValueError):                   # bad alphabet
        PacketTree.from_spec(("a", "h", "v", "x"))


def test_pyramid_tree_matches_dwt2_subbands():
    """wpt2 over the 'dwt:L' tree is dwt2 with its bands re-labelled."""
    x = _img((20, 28))
    pk = wpt2(x, packet="dwt:2")
    pyr = dwt2(x, levels=2)
    np.testing.assert_array_equal(np.asarray(pk["aa"]),
                                  np.asarray(pyr.ll))
    # Pyramid.details is deepest-first: details[0] are the level-2
    # bands (paths ah/av/ad), details[1] the level-1 bands (h/v/d)
    for path, band in (("h", 0), ("v", 1), ("d", 2)):
        np.testing.assert_array_equal(np.asarray(pk[path]),
                                      np.asarray(pyr.details[1][band]))
        np.testing.assert_array_equal(np.asarray(pk["a" + path]),
                                      np.asarray(pyr.details[0][band]))


def test_best_basis_prunes_and_reconstructs():
    # a smooth ramp concentrates energy in approximation nodes: the
    # chosen basis must be admissible and cheaper than (or equal to)
    # both extremes under the same cost
    x = np.outer(np.linspace(0, 1, 32), np.linspace(0, 1, 32)) \
        .astype(np.float32)
    tree = best_basis(x, depth=2, cost="shannon")
    assert isinstance(tree, PacketTree) and tree.depth <= 2
    pk = wpt2(x, packet=tree)
    np.testing.assert_allclose(np.asarray(iwpt2(pk)), x,
                               rtol=RTOL, atol=ATOL)


def test_best_basis_from_costs_split_vs_keep():
    # Coifman-Wickerhauser: a node splits iff its children's summed
    # cost beats its own.  "v" (30 > 4 x 5) splits; "h" (10 < 20) stays.
    costs = {"": 100.0}
    for c in "ahvd":
        costs[c] = 30.0 if c == "v" else 10.0
        for cc in "ahvd":
            costs[c + cc] = 5.0
    tree = best_basis_from_costs(costs, depth=2)
    assert "h" in tree.leaves and "v" not in tree.leaves
    assert {"va", "vh", "vv", "vd"} <= set(tree.leaves)


# -- acceptance matrix: every scheme, depth/levels >= 2, odd shapes ----

@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_packet_roundtrip_every_scheme_odd_shape(scheme):
    x = _img((2, 5 * 4, 7 * 4), seed=3)       # odd/prime multipliers
    pk = wpt2(x, packet="full:2", scheme=scheme)
    assert len(pk.leaves) == 16
    np.testing.assert_allclose(np.asarray(iwpt2(pk, scheme=scheme)), x,
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_dwt3_roundtrip_every_scheme_odd_shape(scheme):
    x = _img((2, 3 * 4, 5 * 4, 7 * 4), seed=4)
    pyr = dwt3(x, levels=2, scheme=scheme)
    assert pyr.levels == 2 and len(pyr.details[0]) == 7
    np.testing.assert_allclose(np.asarray(idwt3(pyr, scheme=scheme)), x,
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("backend", ["jnp", "xla", "pallas"])
def test_workloads_cross_backend_parity(backend):
    """Every backend's packet leaves and 3-D subbands match the eager
    jnp reference to fp32 tolerance."""
    import jax
    x2 = _img((12, 20), seed=5)
    ref2 = wpt2(x2, packet="full:2")
    got2 = wpt2(x2, packet="full:2", backend=backend)
    for a, b in zip(got2.leaves, ref2.leaves):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    x3 = _img((4, 12, 20), seed=6)
    ref3 = jax.tree_util.tree_flatten(dwt3(x3, levels=2))[0]
    got3 = jax.tree_util.tree_flatten(
        dwt3(x3, levels=2, backend=backend))[0]
    for a, b in zip(got3, ref3):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# -- plan layer ---------------------------------------------------------

def test_packet_plans_cache_by_canonical_tree():
    cache = PlanCache()
    p1 = get_plan(shape=(16, 16), packet="full:2", cache=cache)
    p2 = get_plan(shape=(16, 16), packet=tuple(reversed(p1.key.packet)),
                  cache=cache)
    assert p1 is p2 and cache.stats()["misses"] == 1
    assert p1.key.levels == 2 and len(p1.key.packet) == 16


def test_pyramid_fuse_demotes_for_packet_and_3d():
    cache = PlanCache()
    p = get_plan(shape=(16, 16), packet="full:2", fuse="pyramid",
                 backend="jnp", cache=cache)
    assert p.key.fuse == "levels"
    assert "fuse='levels'" in (p.fallback or "")
    p3 = get_plan(shape=(4, 16, 16), ndim=3, fuse="pyramid",
                  backend="jnp", cache=cache)
    assert p3.key.fuse == "levels"


def test_pallas_3d_temporal_fuse_fallback_recorded():
    assert get_backend("pallas").temporal_fuse is False
    cache = PlanCache()
    p = get_plan(shape=(4, 16, 16), ndim=3, fuse="levels",
                 backend="pallas", cache=cache)
    assert "temporal" in (p.fallback or "")


def test_backend_validate_rejects_pyramid_packet_key():
    from repro.engine.plan import PlanKey
    key = PlanKey("cdf97", "ns-polyconv", 1, (16, 16), "float32", "jnp",
                  False, "pyramid", "periodic", "float32", "full", None,
                  packet=("a", "h", "v", "d"))
    with pytest.raises(BackendError):
        get_backend("jnp").validate(key)


def test_workload_key_validation_errors():
    cache = PlanCache()
    with pytest.raises(ValueError):        # packet + 3-D is not a thing
        get_plan(shape=(4, 16, 16), packet="full:2", ndim=3, cache=cache)
    with pytest.raises(ValueError):        # packet + tiled plans
        get_plan(shape=(64, 64), packet="full:2", tiles=(32, 32),
                 cache=cache)
    with pytest.raises(ValueError):        # T not divisible by 2^levels
        get_plan(shape=(6, 16, 16), ndim=3, levels=2, cache=cache)
    with pytest.raises(ValueError):        # rank too low for a volume
        get_plan(shape=(16, 16), ndim=3, cache=cache)


def test_capabilities_expose_workload_flags():
    for row in engine.stats()["backends"]:
        assert row["packets"] is True
        assert row["supports_3d"] is True
        assert row["temporal_fuse"] is (row["backend"] != "pallas")


def test_degradation_chain_carries_workload_fields():
    """faults-plane re-resolution keeps packet/ndim on the degraded
    keys (dataclasses.replace path)."""
    from repro.faults.degrade import degradation_chain
    cache = PlanCache()
    p = get_plan(shape=(16, 16), packet="full:2", backend="xla",
                 fuse="levels", cache=cache)
    chain = degradation_chain(p.key)
    assert chain and all(k.packet == p.key.packet for k in chain)


# -- serving exposure ---------------------------------------------------

def _run(coro):
    return asyncio.run(coro)


def test_serve_packet_and_volume_ops():
    from repro.serve import DwtServer, ServeConfig

    async def main():
        async with DwtServer(ServeConfig(max_batch=4,
                                         max_wait_ms=1.0)) as srv:
            imgs = [_img((20, 28), seed=i) for i in range(3)]
            pks = await asyncio.gather(
                *[srv.submit_wpt2(x, packet="full:2") for x in imgs])
            recs = await asyncio.gather(
                *[srv.submit_iwpt2(pk) for pk in pks])
            for x, pk, r in zip(imgs, pks, recs):
                ref = wpt2(x, packet="full:2")
                assert pk.paths == ref.paths
                for a, b in zip(pk.leaves, ref.leaves):
                    np.testing.assert_allclose(a, np.asarray(b),
                                               rtol=2e-4, atol=2e-5)
                np.testing.assert_allclose(r, x, rtol=RTOL, atol=ATOL)
            vols = [_img((4, 12, 20), seed=10 + i) for i in range(3)]
            p3s = await asyncio.gather(
                *[srv.submit_dwt3(v, levels=2) for v in vols])
            recs3 = await asyncio.gather(
                *[srv.submit_idwt3(p) for p in p3s])
            for v, r in zip(vols, recs3):
                assert r.shape == v.shape
                np.testing.assert_allclose(r, v, rtol=RTOL, atol=ATOL)
            return srv.stats()

    stats = _run(main())
    # wpt2 / iwpt2 / dwt3 / idwt3 each coalesced into its own bucket
    assert stats["buckets_seen"] == 4


def test_bucket_key_canonicalizes_packet_spellings():
    from repro.serve import bucket as BK
    common = dict(wavelet="cdf97", scheme="ns-polyconv", levels=1,
                  backend="jnp", optimize=False, fuse="levels",
                  boundary="periodic", compute_dtype="float32",
                  tap_opt="full")
    k1 = BK.request_key((16, 16), "float32", op="wpt2",
                        packet="full:2", **common)
    leaves = PacketTree.from_spec("full:2").leaves
    k2 = BK.request_key((16, 16), "float32", op="wpt2",
                        packet=tuple(reversed(leaves)), **common)
    assert k1 == k2 and k1.levels == 2
    kw = k1.plan_kwargs(4)
    assert kw["shape"] == (4, 16, 16) and kw["packet"] == leaves
    k3 = BK.request_key((8, 16, 16), "float32", op="dwt3", **common)
    assert k3.t == 8 and k3.plan_kwargs(2)["ndim"] == 3
    with pytest.raises(ValueError):       # packet op needs a spec
        BK.request_key((16, 16), "float32", op="iwpt2", **common)
    with pytest.raises(ValueError):       # 2-D op given a volume
        BK.request_key((8, 16, 16), "float32", op="dwt2", **common)
    with pytest.raises(ValueError):       # 3-D op given an image
        BK.request_key((16, 16), "float32", op="idwt3", **common)
